"""Oracle feature cache: optimality, correctness and pressure tests.

Three layers of evidence that ``policy="oracle"`` is what it claims:

* **property battery** — on randomized traces and capacities the oracle
  never misses more than LRU or clock, gathered bytes are identical
  across all three policies, and on duplicate-free traces its miss count
  *equals* an independent brute-force Belady reference
  (``cache_oracle.belady_min_misses`` — no shared code).  Seeded
  versions always run; hypothesis versions run when the package is
  installed.  ``REPRO_SLOW=1`` (scripts/test.sh RUN_SLOW tier) raises
  the example budgets.
* **unit coverage** — the schedule's next-use table against a naive
  recomputation, overrun freezing, the admit-truncation regression
  (highest-``counts`` candidates win an over-capacity batch), LRU
  stamp refresh, and modeled eviction writeback charging.
* **pressure** — a capacity 10x under the working set driven through
  the pipelined executor with ``check_cache_invariants=True``: the
  slot_of/node_at bijection is asserted from the *consumer* thread
  after every minibatch while the producer admits, and the
  device-resident transfer (``DeviceFeatureTable``) must stay
  byte-exact under that interleaving.
"""
import os
import threading

import numpy as np
import pytest

from repro.core import (AgnesConfig, AgnesEngine, FeatureCache, IOStats,
                        NVMeModel, trace_from_plan)
from repro.core.cache_oracle import (NEVER, OracleSchedule,
                                     belady_min_misses)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SLOW = os.environ.get("REPRO_SLOW", "0") == "1"
N_SEEDS = 300 if SLOW else 60          # seeded battery width
HYP_EXAMPLES = 200 if SLOW else 40     # hypothesis example budget


# ---------------------------------------------------------------- harness
def _random_trace(rng, *, unique_steps=False):
    n_nodes = int(rng.integers(5, 40))
    n_steps = int(rng.integers(3, 15))
    cap = int(rng.integers(1, 10))
    trace = []
    for _ in range(n_steps):
        step = rng.integers(0, n_nodes,
                            size=int(rng.integers(0, 12))).astype(np.int64)
        trace.append(np.unique(step) if unique_steps else step)
    return trace, n_nodes, cap


def _run_policy(trace, capacity, n_nodes, policy, dim=3):
    """Drive one cache through a trace; return (misses, gathered rows)."""
    feats = np.arange(n_nodes * dim, dtype=np.float32).reshape(n_nodes, dim)
    cache = FeatureCache(capacity, n_nodes, dim, admit_threshold=1,
                         policy=policy)
    if policy == "oracle":
        cache.set_oracle(OracleSchedule.from_trace(trace, n_nodes))
    gathered = []
    for step in trace:
        cache.oracle_advance()
        nodes = np.asarray(step, dtype=np.int64)
        out = np.empty((len(nodes), dim), dtype=np.float32)
        cache.note_access(nodes)
        mask, rows = cache.lookup(nodes)
        out[mask] = rows
        miss = nodes[~mask]
        out[~mask] = feats[miss]
        cache.admit(miss, feats[miss])
        cache.check_invariants()
        gathered.append(out)
        assert len(cache) <= max(capacity, 1)
    return cache.stats.cache_misses, gathered


def _assert_oracle_properties(trace, n_nodes, cap, *, unique_steps):
    results = {p: _run_policy(trace, cap, n_nodes, p)
               for p in ("clock", "lru", "oracle")}
    m_clock, m_lru, m_orc = (results[p][0]
                             for p in ("clock", "lru", "oracle"))
    # MIN property: the oracle never misses more than either heuristic
    assert m_orc <= m_clock, f"oracle {m_orc} > clock {m_clock}"
    assert m_orc <= m_lru, f"oracle {m_orc} > lru {m_lru}"
    # byte parity: a policy moves I/O, never bytes
    for p in ("clock", "lru"):
        for a, b in zip(results[p][1], results["oracle"][1]):
            np.testing.assert_array_equal(a, b)
    if unique_steps:
        # exact agreement with the independent brute-force reference
        # (guaranteed for duplicate-free steps; see belady_min_misses)
        ref = belady_min_misses(trace, cap)
        assert m_orc == ref, f"oracle {m_orc} != belady reference {ref}"


# ------------------------------------------------------- property battery
@pytest.mark.parametrize("unique_steps", [False, True])
def test_oracle_property_battery_seeded(unique_steps):
    """Always-on randomized battery (hypothesis-free fallback)."""
    for seed in range(N_SEEDS):
        rng = np.random.default_rng(seed)
        trace, n_nodes, cap = _random_trace(rng, unique_steps=unique_steps)
        _assert_oracle_properties(trace, n_nodes, cap,
                                  unique_steps=unique_steps)


def test_oracle_beats_heuristics_on_adversarial_loop():
    """The classic MIN showcase: a cyclic scan one row larger than the
    cache. LRU/clock evict exactly the row needed next (0% hits after
    warmup); MIN keeps capacity-1 rows pinned."""
    n, cap, reps = 6, 5, 20
    trace = [np.array([v]) for _ in range(reps) for v in range(n)]
    m_clock, _ = _run_policy(trace, cap, n, "clock")
    m_lru, _ = _run_policy(trace, cap, n, "lru")
    m_orc, _ = _run_policy(trace, cap, n, "oracle")
    assert m_lru == n * reps               # pathological for recency
    assert m_orc == belady_min_misses(trace, cap)
    assert m_orc < m_clock and m_orc < m_lru
    assert m_orc <= n + (reps - 1) * 1 + cap  # ~1 rotating miss per lap


if HAVE_HYPOTHESIS:
    @st.composite
    def traces(draw, unique_steps=False):
        n_nodes = draw(st.integers(4, 40))
        cap = draw(st.integers(1, 10))
        steps = draw(st.lists(
            st.lists(st.integers(0, n_nodes - 1), min_size=0, max_size=12),
            min_size=1, max_size=12))
        trace = [np.unique(np.asarray(s, dtype=np.int64)) if unique_steps
                 else np.asarray(s, dtype=np.int64) for s in steps]
        return trace, n_nodes, cap

    @given(traces())
    @settings(max_examples=HYP_EXAMPLES, deadline=None)
    def test_oracle_dominance_hypothesis(tc):
        trace, n_nodes, cap = tc
        _assert_oracle_properties(trace, n_nodes, cap, unique_steps=False)

    @given(traces(unique_steps=True))
    @settings(max_examples=HYP_EXAMPLES, deadline=None)
    def test_oracle_equals_belady_hypothesis(tc):
        trace, n_nodes, cap = tc
        _assert_oracle_properties(trace, n_nodes, cap, unique_steps=True)


# ------------------------------------------------------- schedule units
def test_schedule_next_use_matches_naive():
    rng = np.random.default_rng(7)
    trace = [np.unique(rng.integers(0, 30, size=8)) for _ in range(12)]
    sched = OracleSchedule.from_trace(trace, 30)
    for t, step in enumerate(trace):
        sched.advance()
        assert sched.step == t
        for v in step:
            naive = NEVER
            for u in range(t + 1, len(trace)):
                if v in trace[u]:
                    naive = u
                    break
            assert sched.next_use_of([v])[0] == naive
    assert sched.overruns == 0


def test_schedule_overrun_freezes_not_raises():
    sched = OracleSchedule.from_trace([np.array([1, 2])], 4)
    sched.advance()
    before = sched.next_use.copy()
    for _ in range(3):
        sched.advance()
    assert sched.overruns == 3
    np.testing.assert_array_equal(sched.next_use, before)
    sched.reset()
    assert sched.step == -1 and sched.overruns == 0
    assert (sched.next_use == NEVER).all()


def test_schedule_empty_and_ragged_traces():
    sched = OracleSchedule.from_trace([np.zeros(0, np.int64),
                                       np.array([3]),
                                       np.zeros(0, np.int64),
                                       np.array([3])], 5)
    sched.advance()                         # step 0 (empty)
    assert sched.next_use_of([3])[0] == NEVER   # not yet announced
    sched.advance()                         # step 1: 3 accessed
    assert sched.next_use_of([3])[0] == 3   # next access is step 3
    sched.advance()                         # step 2 (empty)
    assert sched.next_use_of([3])[0] == 3
    sched.advance()                         # step 3: last access
    assert sched.next_use_of([3])[0] == NEVER
    empty = OracleSchedule.from_trace([], 5)
    assert empty.n_steps == 0


def test_trace_from_plan_dedupes_per_minibatch():
    plan = [[np.array([3, 1, 3]), np.array([2, 2])], [np.array([1])], []]
    tr = trace_from_plan(plan)
    assert len(tr) == 3
    np.testing.assert_array_equal(tr[0], [1, 3, 2])
    np.testing.assert_array_equal(tr[1], [1])
    assert len(tr[2]) == 0


def test_oracle_requires_matching_policy():
    cache = FeatureCache(4, 10, 2, policy="clock")
    with pytest.raises(ValueError, match="policy='oracle'"):
        cache.set_oracle(OracleSchedule.from_trace([np.array([1])], 10))
    with pytest.raises(ValueError, match="unknown cache policy"):
        FeatureCache(4, 10, 2, policy="belady")


# ------------------------------------------------------------ cache units
def test_admit_overflow_keeps_hottest_candidates():
    """Regression: an over-capacity batch used to drop an arbitrary tail;
    it must keep the highest-``counts`` candidates."""
    cap, n = 4, 12
    cache = FeatureCache(cap, n, 2, admit_threshold=1, policy="clock")
    nodes = np.arange(10)
    counts = np.array([1, 1, 1, 1, 1, 1, 9, 8, 7, 6])
    for v, c in zip(nodes, counts):
        cache.counts[v] = c
    rows = np.arange(20, dtype=np.float32).reshape(10, 2)
    admitted = cache.admit(nodes, rows)
    assert admitted == cap
    assert set(cache.resident_nodes()) == {6, 7, 8, 9}
    # and the rows landed intact
    for v in (6, 7, 8, 9):
        mask, r = cache.lookup(np.array([v]))
        assert mask[0]
        np.testing.assert_array_equal(r[0], rows[v])
    cache.check_invariants()


def test_lru_evicts_stalest_and_hits_refresh():
    cache = FeatureCache(2, 10, 2, admit_threshold=1, policy="lru")
    rows = np.arange(20, dtype=np.float32).reshape(10, 2)
    cache.note_access([0, 1])
    cache.admit(np.array([0, 1]), rows[[0, 1]])
    cache.lookup(np.array([0]))          # refresh 0: now 1 is stalest
    cache.note_access([2])
    cache.admit(np.array([2]), rows[[2]])
    assert set(cache.resident_nodes()) == {0, 2}
    cache.check_invariants()


def test_eviction_writeback_is_charged():
    stats = IOStats()
    cache = FeatureCache(2, 10, 4, admit_threshold=1, policy="clock",
                         stats=stats)
    cache.attach_writeback(NVMeModel(), queue_depth=4)
    rows = np.arange(40, dtype=np.float32).reshape(10, 4)
    for batch in ([0, 1], [2, 3], [4]):
        nodes = np.array(batch)
        cache.note_access(nodes)
        cache.admit(nodes, rows[nodes])
    assert stats.cache_evictions == 3     # 2 + 1 displaced
    assert stats.n_writes == 3            # row-granular requests
    assert stats.bytes_written == 3 * cache.row_bytes
    assert stats.modeled_write_time > 0
    # without attach_writeback evictions count but cost nothing
    bare = FeatureCache(2, 10, 4, admit_threshold=1)
    for batch in ([0, 1], [2, 3]):
        nodes = np.array(batch)
        bare.note_access(nodes)
        bare.admit(nodes, rows[nodes])
    assert bare.stats.cache_evictions == 2
    assert bare.stats.n_writes == 0


def test_oracle_never_admits_dead_rows():
    """Rows with no future use must not displace anything."""
    trace = [np.array([0, 1]), np.array([2, 3]), np.array([0, 1])]
    n, cap = 6, 2
    misses, _ = _run_policy(trace, cap, n, "oracle")
    # 0/1 admitted at step 0, kept through step 1 (2/3 are dead), hit at 2
    assert misses == 4


# ------------------------------------------------- engine-level recording
def test_engine_records_and_replays_trace(tiny_ds):
    """k-hop flow: record the gather trace, then replay the same plan
    under the oracle — misses must not exceed the recording epoch's."""
    g, f = tiny_ds.reopen_stores()
    cfg = AgnesConfig(block_size=16384, minibatch_size=32,
                      hyperbatch_size=2, fanouts=(3,),
                      graph_buffer_bytes=1 << 20,
                      feature_buffer_bytes=1 << 18, async_io=False,
                      cache_policy="oracle", cache_capacity_rows=96,
                      cache_admit_threshold=1, record_feature_trace=True)
    eng = AgnesEngine(g, f, cfg)
    targets = np.arange(192)
    plan = eng.plan_epoch(targets, epoch=0)
    # recording epoch: oracle policy without a schedule falls back to
    # counted admission — the trace lands in eng.feature_trace
    rec_feats = [p.features for mbs in plan
                 for p in eng.prepare(mbs, epoch=0)]
    n_steps = len(plan)
    assert len(eng.feature_trace) == n_steps
    rec_misses = eng.feature_cache.stats.cache_misses
    sched = eng.install_cache_oracle()           # replays feature_trace
    assert sched.n_steps == n_steps
    before = eng.feature_cache.stats.cache_misses
    rep_feats = [p.features for mbs in plan
                 for p in eng.prepare(mbs, epoch=0)]
    rep_misses = eng.feature_cache.stats.cache_misses - before
    assert rep_misses <= rec_misses
    assert sched.overruns == 0
    for a, b in zip(rec_feats, rep_feats):
        np.testing.assert_array_equal(a, b)
    eng.close()


def test_zero_hop_recorded_trace_matches_plan(tiny_ds):
    g, f = tiny_ds.reopen_stores()
    cfg = AgnesConfig(block_size=16384, minibatch_size=32,
                      hyperbatch_size=2, fanouts=(),
                      graph_buffer_bytes=1 << 20,
                      feature_buffer_bytes=1 << 18, async_io=False,
                      record_feature_trace=True)
    eng = AgnesEngine(g, f, cfg)
    plan = eng.plan_epoch(np.arange(128), epoch=0)
    for mbs in plan:
        eng.prepare(mbs, epoch=0)
    expect = trace_from_plan(plan)
    assert len(eng.feature_trace) == len(expect)
    for a, b in zip(eng.feature_trace, expect):
        np.testing.assert_array_equal(a, b)
    eng.close()


# ------------------------------------------------------ eviction pressure
class _TableStubTrainer:
    """Minimal consumer: lands every minibatch through the device table
    (byte-parity asserted) instead of training — the executor only needs
    ``train_minibatch``."""

    def __init__(self, table):
        self.table = table
        self.n = 0

    def train_minibatch(self, prepared) -> float:
        dv = prepared.to_device(backend="pallas", table=self.table)
        n = prepared.features.shape[0]
        got = np.asarray(dv.features)
        np.testing.assert_array_equal(got[:n], prepared.features)
        assert (got[n:] == 0).all()
        self.n += 1
        return 0.0


@pytest.mark.parametrize("policy", ["clock", "lru"])
def test_eviction_pressure_pipelined(tiny_ds, policy):
    """Capacity 10x under the working set, invariants checked from the
    consumer thread every minibatch while the producer admits, and the
    HBM-resident transfer stays byte-exact under the interleaving."""
    from repro.gnn import PipelinedExecutor

    g, f = tiny_ds.reopen_stores()
    targets = np.arange(256)
    working_set = 256  # 0-hop: inputs == targets
    cfg = AgnesConfig(block_size=16384, minibatch_size=32,
                      hyperbatch_size=2, fanouts=(),
                      graph_buffer_bytes=1 << 20,
                      feature_buffer_bytes=1 << 18, async_io=False,
                      cache_policy=policy,
                      cache_capacity_rows=working_set // 10,
                      cache_admit_threshold=1, cache_writeback=True)
    eng = AgnesEngine(g, f, cfg)
    assert eng.feature_cache.capacity == working_set // 10
    trainer = _TableStubTrainer(eng.device_feature_table())
    with PipelinedExecutor(eng, trainer, depth=2,
                           check_cache_invariants=True) as ex:
        for epoch in range(3):
            rep = ex.run_epoch(targets, epoch=epoch)
            assert rep.n_minibatches == 8
    assert trainer.n == 24
    st = eng.feature_cache.stats
    assert st.cache_evictions > 0, "pressure test never evicted"
    assert f.stats.n_writes > 0, "writeback never charged"
    eng.feature_cache.check_invariants()
    eng.close()


def test_concurrent_admit_and_table_sync_race():
    """Hammer admit from one thread while resolving/syncing the device
    table from another; every resolved slot must serve the right bytes."""
    from repro.core import DeviceFeatureTable, ResidentSplit

    n, cap, dim = 400, 32, 8
    feats = np.arange(n * dim, dtype=np.float32).reshape(n, dim)
    cache = FeatureCache(cap, n, dim, admit_threshold=1, policy="clock")
    table = DeviceFeatureTable(cache)
    stop = threading.Event()
    errors = []

    def producer():
        rng = np.random.default_rng(1)
        try:
            while not stop.is_set():
                nodes = np.unique(rng.integers(0, n, size=16))
                cache.note_access(nodes)
                cache.admit(nodes, feats[nodes])
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    rng = np.random.default_rng(2)
    try:
        for _ in range(200):
            nodes = np.unique(rng.integers(0, n, size=24))
            slots = cache.lookup_slots(nodes)
            hit = np.nonzero(slots >= 0)[0]
            split = ResidentSplit(hit, slots[hit], nodes[hit])
            out_slots, host_pos = table.resolve(split, len(nodes),
                                                len(nodes))
            served = np.nonzero(out_slots >= 0)[0]
            if served.size:
                got = np.asarray(table.array)[out_slots[served],
                                              :dim]
                np.testing.assert_array_equal(got, feats[nodes[served]])
            cache.check_invariants()
    finally:
        stop.set()
        t.join(timeout=10)
    assert not errors
    assert table.hit_rows_served > 0, "race test never served a hit"
