import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: the three selected cells, baseline vs change.

Each experiment is one hypothesis -> change -> re-lower -> re-analyse
cycle on the cell's dominant roofline term (EXPERIMENTS.md §Perf):

  gemma3-27b x train_4k     : FSDP all-gathers dominate collectives for a
                              model that fits TP-only -> raise threshold
  deepseek-moe-16b x train_4k: 16-way TP over d_model=2048 is collective-
                              bound -> EP + 256-way full DP
  jamba-398b x train_4k     : one-hot dispatch flops scale with group
                              size -> halve group_tokens
"""
import dataclasses
import json

from ..configs import get_config
from .mesh import make_production_mesh
from .roofline import roofline_cell

EXPERIMENTS = [
    {
        "cell": ("gemma3-27b", "train_4k"),
        "name": "fsdp-off (params fit TP-only at 3.4 GB/dev)",
        "hypothesis": "per-microbatch FSDP all-gathers of 27B params "
                      "dominate the collective term; TP-only sharding "
                      "removes them at +3.4 GB/dev memory",
        "kwargs": {"fsdp_threshold": 1 << 62},
    },
    {
        "cell": ("deepseek-moe-16b", "train_4k"),
        "name": "EP + 256-way full DP (replicated dense weights)",
        "hypothesis": "TP=16 over d_model=2048 leaves 128-wide shards: "
                      "2 activation all-reduces/layer dominate; sharding "
                      "batch over model instead removes TP collectives "
                      "(dense weights replicate: ~1 GB/dev)",
        "kwargs": {"extra_overrides": {"dp_over_model": True}},
    },
    {
        "cell": ("jamba-1.5-large-398b", "train_4k"),
        "name": "halve MoE dispatch group (4096 -> 2048 tokens)",
        "hypothesis": "GShard one-hot dispatch flops per token scale "
                      "linearly with group size; halving the group "
                      "halves dispatch compute at unchanged expert flops "
                      "(more, smaller all-to-alls: same bytes)",
        "kwargs": {},   # group override built per-cfg below
    },
]


def main():
    mesh = make_production_mesh()
    out = []
    for exp in EXPERIMENTS:
        arch, shape = exp["cell"]
        print(f"[perf] {arch} x {shape}: baseline ...", flush=True)
        base = roofline_cell(arch, shape, mesh)
        kwargs = dict(exp["kwargs"])
        if arch.startswith("jamba"):
            cfg = get_config(arch)
            kwargs["extra_overrides"] = {
                "moe": dataclasses.replace(cfg.moe, group_tokens=2048)}
        print(f"[perf] {arch} x {shape}: {exp['name']} ...", flush=True)
        var = roofline_cell(arch, shape, mesh, **kwargs)
        rec = {
            "cell": exp["cell"], "name": exp["name"],
            "hypothesis": exp["hypothesis"],
            "before": {"terms_s": base["terms_s"],
                       "dominant": base["dominant"],
                       "bound_mfu": base["bound_mfu"],
                       "collectives": base["collectives_by_op"]},
            "after": {"terms_s": var["terms_s"],
                      "dominant": var["dominant"],
                      "bound_mfu": var["bound_mfu"],
                      "collectives": var["collectives_by_op"]},
        }
        b, a = base["terms_s"], var["terms_s"]
        rec["delta"] = {kk: round((a[kk] - b[kk]) / max(b[kk], 1e-12), 4)
                        for kk in b}
        rec["verdict"] = ("confirmed"
                          if a[base["dominant"]] < b[base["dominant"]]
                          else "refuted")
        out.append(rec)
        print(f"  before {b} mfu={base['bound_mfu']}")
        print(f"  after  {a} mfu={var['bound_mfu']}  -> {rec['verdict']}",
              flush=True)
        os.makedirs("results", exist_ok=True)
        with open("results/perf_cells.json", "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
