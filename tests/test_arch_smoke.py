"""Per-architecture smoke: reduced config, one forward + one train step on
CPU, asserting output shapes and finiteness (assignment deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs, smoke_reduce
from repro.models import build_model
from repro.train.loop import make_serve_step, make_train_step
from repro.train.optimizer import adamw_init

ARCHS = list_configs()


def _smoke_batch(cfg, B=2, S=32, n_micro=1, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    toks = rng.integers(1, cfg.vocab, (n_micro, B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.n_enc_layers:
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(n_micro, B, cfg.enc_seq, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(n_micro, B, 8, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke_reduce(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    # forward loss is a finite scalar near ln(vocab) for random tokens
    loss = jax.jit(model.loss)(params, jax.tree.map(lambda x: x[0], batch))
    assert jnp.isfinite(loss), arch
    assert 0.1 < float(loss) < 3 * np.log(cfg.vocab), (arch, float(loss))
    # one optimizer step moves the loss
    step = jax.jit(make_train_step(model, n_microbatches=1, lr=1e-3))
    opt = adamw_init(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    loss2 = jax.jit(model.loss)(params2, jax.tree.map(lambda x: x[0], batch))
    assert jnp.isfinite(loss2)
    assert float(loss2) < float(loss), f"{arch}: step did not reduce loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = smoke_reduce(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, max_len = 2, 16
    caches = model.init_cache(B, max_len)
    serve = jax.jit(make_serve_step(model))
    toks = jnp.ones((B,), jnp.int32)
    for pos in range(3):
        toks, logits, caches = serve(params, caches, toks,
                                     jnp.asarray(pos, jnp.int32))
        assert logits.shape == (B, cfg.vocab), arch
        assert bool(jnp.isfinite(logits).all()), arch
        assert toks.shape == (B,)


def test_decode_matches_forward_smollm():
    """Teacher-forced decode logits == forward logits (causal consistency)."""
    cfg = smoke_reduce(get_config("smollm-360m"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 1, 8
    toks = np.random.default_rng(0).integers(1, cfg.vocab, (B, S))
    h, _ = model.hidden_states(params, jnp.asarray(toks, jnp.int32))
    from repro.models.common import rms_norm  # full logits via tied head
    logits_fwd = (h @ params["embed"].T).astype(jnp.float32)
    caches = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, caches,
                                       jnp.asarray(toks[:, t], jnp.int32),
                                       jnp.asarray(t, jnp.int32))
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_fwd),
                               rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ["gemma3-27b", "jamba-1.5-large-398b",
                                  "moonshot-v1-16b-a3b"])
def test_stack_plan_covers_all_layers(arch):
    cfg = get_config(arch)
    o, p, k, t = cfg.stack_plan()
    assert o + p * k + t == cfg.n_layers
    assert cfg.layers[o:o + p * k] == cfg.layers[o:o + p] * k


def test_param_counts_near_published():
    targets = {"gemma3-27b": 27e9, "smollm-360m": 0.36e9,
               "jamba-1.5-large-398b": 398e9, "deepseek-moe-16b": 16.4e9,
               "xlstm-1.3b": 1.3e9, "qwen2-vl-2b": 1.5e9}
    for arch, want in targets.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.25, (arch, got, want)
