"""I/O scheduler before/after: per-block path vs coalesced + batched.

Fig-11-style bandwidth-utilization measurement for the coalescing
scheduler (``repro.core.io_sched``): the same hyperbatch prepare is run
through the legacy per-block path (one ``block_size`` request per block,
serialized, per-request latency) and through the coalesced multi-block
scheduler (adjacent runs merged up to ``max_coalesce_bytes``, submitted
at queue depth, charged via ``NVMeModel.batch_time``).

The workload recreates the paper's billion-node geometry at container
scale: a block count much larger than the blocks a hyperbatch touches,
so the visit plan has gaps and short runs — exactly where per-request
latency dominates.  Small blocks stand in for a large graph; the
modeled-time ratio is what transfers.

Emits rows and returns a dict (consumed by ``run.py --quick`` for
``BENCH_io.json``).  MFG/feature equality between the two paths is
asserted here as well — the speedup must be free.
"""
from __future__ import annotations

import numpy as np

from .common import emit, get_dataset, make_agnes, quick_val, targets_for

MIN_SPEEDUP = 2.0  # coalesced vs per-block, asserted below + CI-guarded


def _measure(eng, targets):
    prepared = eng.prepare(targets, epoch=0)
    g, f = eng.graph_store.stats, eng.feature_store.stats
    t = g.modeled_read_time + f.modeled_read_time
    nbytes = g.bytes_read + f.bytes_read
    reads = g.n_reads + f.n_reads
    reqs = g.n_requests + f.n_requests
    seq = g.n_sequential_reads + f.n_sequential_reads
    return prepared, {
        "modeled_prepare_io_s": t,
        "bytes_read": int(nbytes),
        "n_reads": int(reads),
        "n_requests": int(reqs),
        "n_sequential_reads": int(seq),
        "sequential_fraction": round(seq / reads, 4) if reads else 0.0,
        "achieved_bw_GBps": round(nbytes / max(t, 1e-12) / 1e9, 3),
    }


def run() -> dict:
    # sparse-touch geometry: many more blocks than a hyperbatch visits
    n_nodes = quick_val(120_000, 6_000)
    block = quick_val(16384, 2048)
    mb = quick_val(48, 24)
    ds = get_dataset("iosparse", dim=32, block_size=block,
                     n_nodes=n_nodes, avg_degree=8)
    out: dict = {"workload": {"n_nodes": ds.n_nodes, "block_size": block,
                              "graph_blocks": ds.graph_store.n_blocks,
                              "feature_blocks": ds.feature_store.n_blocks}}
    for n_ssd in (1, 4):
        targets = targets_for(ds, n_mb=2, mb_size=mb)
        kw = dict(block_size=block, fanouts=(3, 3), minibatch=mb,
                  hyperbatch_size=2, setting_bytes=32 << 20, n_ssd=n_ssd)
        # before: legacy per-block path (scheduler disabled)
        base = make_agnes(ds, max_coalesce_bytes=0, **kw)
        p0, before = _measure(base, targets)
        # after: coalescing + batched submission at default knobs
        eng = make_agnes(ds, **kw)
        p1, after = _measure(eng, targets)
        for a, b in zip(p1, p0):
            for x, y in zip(a.mfg.nodes, b.mfg.nodes):
                assert np.array_equal(x, y), "coalescing changed the MFGs"
            assert np.allclose(a.features, b.features), \
                "coalescing changed gathered features"
        assert after["bytes_read"] == before["bytes_read"], \
            (after["bytes_read"], before["bytes_read"])
        speedup = before["modeled_prepare_io_s"] / max(
            after["modeled_prepare_io_s"], 1e-12)
        # acceptance gate (deterministic: modeled device time of a fixed
        # plan) — coalescing + batched submission must stay >= 2x faster
        # than the per-block path at default knobs
        assert speedup >= MIN_SPEEDUP, \
            f"I/O scheduler regression: {speedup:.2f}x < " \
            f"{MIN_SPEEDUP}x (n_ssd={n_ssd})"
        tag = f"io/ssd{n_ssd}"
        emit(f"{tag}/per_block_ms", before["modeled_prepare_io_s"] * 1e3,
             f"n_requests={before['n_requests']}")
        emit(f"{tag}/coalesced_ms", after["modeled_prepare_io_s"] * 1e3,
             f"n_requests={after['n_requests']} "
             f"seq={after['sequential_fraction']*100:.0f}%")
        emit(f"{tag}/speedup", speedup,
             f"bw {before['achieved_bw_GBps']}->{after['achieved_bw_GBps']} GB/s")
        out[f"ssd{n_ssd}"] = {"per_block": before, "coalesced": after,
                              "speedup": round(speedup, 3)}
        eng.close()
        base.close()
    return out


if __name__ == "__main__":
    print(run())
